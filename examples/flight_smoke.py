"""End-to-end flight-recorder smoke: the CI ``flight-replay-smoke`` job.

Records a short gateway run with forced rung switches (a controller
under an impossible TPOT SLO) and a preemption (interactive arrival over
a full pool of best-effort decoders), then:

1. asserts ``GET /v1/debug/flight`` serves the ring and triggers a dump,
2. drains the gateway and replays the full JSONL recording in a fresh
   process (``python -m repro.obs.flight.replay``), gating whole-trace
   token bit-identity, matching rung residency, identical decision
   streams, and zero post-warmup retraces,
3. asserts the recorded incident actually contains a ``rung_switch``
   and a ``preempt`` decision (the scenario did what it claims),
4. re-runs the replay with ``--inject-divergence`` and asserts it exits
   nonzero with a structured first-divergence report.

Run it directly::

    JAX_PLATFORMS=cpu python examples/flight_smoke.py --out-dir /tmp/flight
"""
import argparse
import json
import os
import signal
import socket
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request

from repro.obs.clock import now

STARTUP_TIMEOUT_S = 300.0
DRAIN_TIMEOUT_S = 120.0
REPLAY_TIMEOUT_S = 300.0


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def wait_healthy(port: int, deadline: float) -> None:
    url = f"http://127.0.0.1:{port}/v1/health"
    while now() < deadline:
        try:
            with urllib.request.urlopen(url, timeout=2) as resp:
                health = json.load(resp)
            assert health["status"] == "ok", health
            return
        except (urllib.error.URLError, ConnectionError, OSError):
            time.sleep(0.5)
    raise SystemExit("gateway never became healthy")


def generate(port: int, prompt, max_new: int, priority: str) -> dict:
    payload = json.dumps({"prompt": list(prompt),
                          "max_new_tokens": max_new,
                          "priority": priority}).encode()
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}/v1/generate", data=payload,
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=120) as resp:
        return json.load(resp)


def build_ladder(path: str) -> None:
    """Save the 3-rung uniform ladder the recorded engine serves with."""
    from repro.configs import get_config, reduced
    from repro.models import api
    from repro.sparsity import PolicyLadder
    cfg = reduced(get_config("llama31_8b"))
    params = api.init_model(cfg, 0)
    PolicyLadder.uniform(params, cfg, [0.0, 0.5, 0.7]).save(path)
    print(f"ladder artifact at {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="/tmp/flight")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    ladder = os.path.join(args.out_dir, "ladder.npz")
    recording = os.path.join(args.out_dir, "gateway.jsonl")
    dump_dir = os.path.join(args.out_dir, "dumps")
    build_ladder(ladder)

    port = free_port()
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--gateway",
         "--gateway-port", str(port), "--max-queue", "8", "--preemption",
         "--prompt-len", "16", "--gen", "1024", "--batch", "2", "--chunk", "8",
         "--ladder", ladder, "--slo-tpot-p95", "1e-9",
         "--flight-record", recording, "--flight-ring", "32768",
         "--flight-dump-dir", dump_dir])
    try:
        wait_healthy(port, now() + STARTUP_TIMEOUT_S)

        # two best-effort long generations fill both slots (1024 tokens
        # each keeps both decoding for seconds, so the interactive
        # arrival below reliably lands mid-decode even on fast hosts)...
        threads = [threading.Thread(
            target=generate, args=(port, range(1, 17), 1024, "best_effort"))
            for _ in range(2)]
        for t in threads:
            t.start()
        time.sleep(0.4)
        # ...then an interactive arrival must preempt one of them
        out = generate(port, range(20, 36), 8, "interactive")
        assert len(out["tokens"]) == 8, out
        for t in threads:
            t.join(timeout=120)

        # the debug endpoint serves the ring and triggers an http dump
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/v1/debug/flight",
                timeout=10) as resp:
            snap = json.load(resp)
        assert snap["count"] > 0 and snap["records"], snap["count"]
        assert snap.get("dump_path"), "debug endpoint should trigger a dump"
        print(f"debug endpoint OK: {snap['count']} records, "
              f"dump at {snap['dump_path']}")
    except BaseException:
        proc.kill()
        raise
    proc.send_signal(signal.SIGTERM)
    rc = proc.wait(timeout=DRAIN_TIMEOUT_S)
    assert rc == 0, f"gateway exited {rc}, expected a clean drain (0)"

    # the incident the recording claims: rung switches + a preemption
    with open(recording) as f:
        records = [json.loads(ln) for ln in f if ln.strip()]
    kinds = {(r.get("k"), r.get("kind")) for r in records}
    assert ("decision", "rung_switch") in kinds, "no rung switch recorded"
    assert ("decision", "preempt") in kinds, "no preemption recorded"
    n_finish = sum(1 for r in records if r.get("k") == "finish")
    print(f"recorded {len(records)} records, {n_finish} finishes, "
          f"rung switches + preemption present")

    # bit-identical replay in a fresh process
    out = subprocess.run(
        [sys.executable, "-m", "repro.obs.flight.replay", recording],
        capture_output=True, text=True, timeout=REPLAY_TIMEOUT_S)
    print(out.stdout)
    assert out.returncode == 0, f"replay failed:\n{out.stdout}{out.stderr}"
    report = json.loads(out.stdout)
    assert report["ok"] and not report["failures"], report
    assert all(v == 0 for v in report["retraces"].values()), report
    print(f"replay OK: {report['tokens']} tokens bit-identical, "
          f"retraces {report['retraces']}")

    # injected divergence must exit nonzero with a structured report
    out = subprocess.run(
        [sys.executable, "-m", "repro.obs.flight.replay", recording,
         "--inject-divergence"],
        capture_output=True, text=True, timeout=REPLAY_TIMEOUT_S)
    assert out.returncode == 1, \
        f"injected divergence not caught (exit {out.returncode})"
    report = json.loads(out.stdout)
    div = report["divergence"]
    assert div and "record" in div and "token_index" in div, report
    print(f"divergence report OK: request {div.get('request')} token "
          f"{div.get('token_index')} at record {div['record']}")


if __name__ == "__main__":
    main()
