"""End-to-end driver: train a ~5M-param LM for a few hundred steps on the
synthetic pipeline (with fault-tolerant checkpointing — a simulated
preemption at step 120 restarts transparently), then apply the full
WiSparse pipeline at 30/40/50% sparsity and report accuracy retention —
the paper's Table-1 protocol on an in-repo model.

    PYTHONPATH=src python examples/train_then_sparsify.py [--steps 200]
"""
import os as _os
import sys as _sys
_sys.path.insert(0, _os.path.join(_os.path.dirname(__file__), ".."))
_sys.path.insert(0, _os.path.join(_os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses
import tempfile

import jax.numpy as jnp
import numpy as np

from benchmarks.common import eval_metrics
from repro.core import calibration, pipeline
from repro.core.allocation import EvoConfig
from repro.data import SyntheticLM
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        params, cfg, data_cfg, hist, final = train(
            arch="llama31_8b", use_reduced=True, steps=args.steps,
            batch=8, seq=96, lr=5e-3, ckpt_dir=ckpt_dir, ckpt_every=50,
            fail_at=(120,),        # simulated preemption -> auto restart
        )
    print(f"trained: loss {hist[0]['loss']:.3f} -> {final:.3f}")

    calib = SyntheticLM(dataclasses.replace(data_cfg, global_batch=4)
                        ).batch(991)
    batch = {"tokens": jnp.asarray(calib)}
    ctx = calibration.build_context(params, cfg, batch)

    dense = eval_metrics(params, cfg, data_cfg, None)
    print(f"dense held-out ppl: {dense['ppl']:.3f}")
    evo = EvoConfig(generations=4, offspring=8, eps=0.1)
    for p in (0.3, 0.4, 0.5):
        plan = pipeline.run_pipeline(params, cfg, batch, p, evo=evo,
                                     delta=0.25, coord_passes=0, ctx=ctx)
        m = eval_metrics(params, cfg, data_cfg, plan.per_depth_sp)
        print(f"WiSparse@{p:.0%}: ppl={m['ppl']:.3f} "
              f"retention={dense['ppl']/m['ppl']:.1%} "
              f"top1-agree={m['top1_agree']:.1%}")


if __name__ == "__main__":
    main()
