"""End-to-end gateway smoke: the CI ``gateway-smoke`` job's driver.

Launches ``repro.launch.serve --gateway`` as a real subprocess on a
random free port, then exercises the full client-visible surface:

1. polls ``GET /v1/health`` until the model is warm and serving,
2. streams one request over a raw HTTP/1.1 socket and asserts the SSE
   protocol end to end — chunked transfer framing, one ``data:`` event
   per token with monotonically increasing ``index``, a ``done`` event
   carrying the usage payload, the ``data: [DONE]`` sentinel, and the
   terminating zero-length chunk,
3. scrapes ``GET /metrics`` and validates the exposition with
   ``repro.obs.validate_exposition``,
4. sends SIGTERM and asserts the server drains and exits 0.

Doubles as a reference client: everything here is stdlib + one
validation helper, so it also documents the wire protocol the gateway
speaks.  Run it directly::

    JAX_PLATFORMS=cpu python examples/gateway_smoke.py
"""
import json
import signal
import socket
import subprocess
import sys
import time
import urllib.error
import urllib.request

from repro.obs import validate_exposition
from repro.obs.clock import now

STARTUP_TIMEOUT_S = 300.0
DRAIN_TIMEOUT_S = 120.0


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def wait_healthy(port: int, deadline: float) -> None:
    url = f"http://127.0.0.1:{port}/v1/health"
    while now() < deadline:
        try:
            with urllib.request.urlopen(url, timeout=2) as resp:
                health = json.load(resp)
            assert health["status"] == "ok", health
            return
        except (urllib.error.URLError, ConnectionError, OSError):
            time.sleep(0.5)
    raise SystemExit("gateway never became healthy")


def stream_one(port: int, prompt: list, max_new: int) -> None:
    """One streaming generate over a raw socket; asserts SSE framing."""
    payload = json.dumps({"prompt": prompt, "max_new_tokens": max_new,
                          "priority": "interactive",
                          "stream": True}).encode()
    req = (b"POST /v1/generate HTTP/1.1\r\nHost: smoke\r\n"
           b"Content-Type: application/json\r\n"
           b"Content-Length: " + str(len(payload)).encode()
           + b"\r\n\r\n" + payload)
    with socket.create_connection(("127.0.0.1", port), timeout=60) as s:
        s.sendall(req)
        raw = b""
        while b"0\r\n\r\n" not in raw:
            chunk = s.recv(65536)
            if not chunk:
                break
            raw += chunk
    head, _, rest = raw.partition(b"\r\n\r\n")
    assert b"HTTP/1.1 200" in head, head
    assert b"Transfer-Encoding: chunked" in head, head
    assert b"Content-Type: text/event-stream" in head, head
    body, buf = b"", rest                     # de-chunk
    while buf:
        size, _, buf = buf.partition(b"\r\n")
        if int(size, 16) == 0:
            break
        n = int(size, 16)
        body += buf[:n]
        buf = buf[n + 2:]
    events = [e for e in body.decode().split("\n\n") if e.strip()]
    assert events[-1] == "data: [DONE]", events[-1]
    parsed = [json.loads(e[len("data: "):]) for e in events[:-1]]
    tokens = [e for e in parsed if "token" in e]
    assert [e["index"] for e in tokens] == list(range(max_new)), tokens
    done = parsed[-1]
    assert done.get("done") is True, done
    assert done["usage"] == {"prompt_tokens": len(prompt),
                             "completion_tokens": max_new}, done
    print(f"SSE stream OK: {max_new} token events + usage payload")


def scrape_metrics(port: int) -> None:
    url = f"http://127.0.0.1:{port}/metrics"
    with urllib.request.urlopen(url, timeout=10) as resp:
        text = resp.read().decode()
    n = validate_exposition(text)
    assert n > 0
    for name in ("repro_requests_finished_total", "repro_preemptions_total",
                 "repro_queue_wait_seconds"):
        assert name in text, f"{name} missing from exposition"
    print(f"exposition: {n} samples OK")


def main() -> None:
    port = free_port()
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--gateway",
         "--gateway-port", str(port), "--max-queue", "8", "--preemption",
         "--prompt-len", "16", "--gen", "8", "--batch", "2",
         "--chunk", "8"])
    try:
        wait_healthy(port, now() + STARTUP_TIMEOUT_S)
        stream_one(port, prompt=list(range(1, 9)), max_new=4)
        scrape_metrics(port)
    except BaseException:
        proc.kill()
        raise
    proc.send_signal(signal.SIGTERM)
    rc = proc.wait(timeout=DRAIN_TIMEOUT_S)
    assert rc == 0, f"gateway exited {rc}, expected a clean drain (0)"
    print("SIGTERM drain OK (exit 0)")


if __name__ == "__main__":
    main()
