"""Quickstart: WiSparse in ~40 lines.

Builds a small model, computes weight-aware scores, applies a 50%-sparsity
threshold mask (paper Eq. 4/5) and compares against the dense output.

    PYTHONPATH=src python examples/quickstart.py
"""
import os as _os
import sys as _sys
_sys.path.insert(0, _os.path.join(_os.path.dirname(__file__), ".."))
_sys.path.insert(0, _os.path.join(_os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import pipeline
from repro.core import unstacked as U
from repro.models import api

# 1. a small llama-style model (same family as the paper's Llama-3.1-8B)
cfg = reduced(get_config("llama31_8b"))
params = api.init_model(cfg, seed=0)

# 2. calibration data (synthetic here; pile-val/CodeAlpaca in the paper)
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
batch = {"tokens": tokens}

# 3. one-call WiSparse: weight-aware scores + thresholds at 50% sparsity
#    (tiny search budget so the demo runs in seconds on CPU)
from repro.core.allocation import EvoConfig
plan = pipeline.run_pipeline(
    params, cfg, batch, p_target=0.5,
    evo=EvoConfig(generations=2, offspring=4, eps=0.1),
    delta=0.25, coord_passes=0, log=print)
print("block-level prune ratios:", np.round(plan.block_ratios, 3))

# 4. run the sparse model (per-token masks, Eq. 5) and compare to dense.
#    The execution backend is an explicit SparsityPolicy value, not
#    ambient state: pass it alongside the traced sp params.
from repro.sparsity import SparsityPolicy
dense_logits, _ = U.forward_unstacked(params, cfg, tokens)
sparse_logits, _ = U.forward_unstacked(params, cfg, tokens,
                                       per_depth_sp=plan.per_depth_sp,
                                       policy=SparsityPolicy.uniform("mask"))
pd = jax.nn.log_softmax(dense_logits.astype(jnp.float32), -1)
ps = jax.nn.log_softmax(sparse_logits.astype(jnp.float32), -1)
kl = float(jnp.mean(jnp.sum(jnp.exp(pd) * (pd - ps), -1)))
agree = float((jnp.argmax(pd, -1) == jnp.argmax(ps, -1)).mean())
print(f"50% sparsity: KL(dense||sparse)={kl:.5f}, top-1 agreement={agree:.1%}")
assert np.isfinite(kl)
print("OK")
