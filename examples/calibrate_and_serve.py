"""Serving example: calibrate WiSparse offline, save the plan, reload it in
a "serving fleet" process and run batched greedy decoding with the
weight-aware sparse path (paper §5.1 recipe: dense prefill half, sparse
decode), comparing outputs against the dense server.

    PYTHONPATH=src python examples/calibrate_and_serve.py
"""
import os as _os
import sys as _sys
_sys.path.insert(0, _os.path.join(_os.path.dirname(__file__), ".."))
_sys.path.insert(0, _os.path.join(_os.path.dirname(__file__), "..", "src"))

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import calibration, pipeline
from repro.core.allocation import EvoConfig
from repro.data import DataConfig, SyntheticLM
from repro.launch.serve import generate
from repro.models import api

cfg = reduced(get_config("llama31_8b"))
params = api.init_model(cfg, 0)
data_cfg = DataConfig(cfg.vocab_size, 48, 4)

# --- offline calibration (one-time, per model) -----------------------------
calib = {"tokens": jnp.asarray(SyntheticLM(data_cfg).batch(0))}
plan = pipeline.run_pipeline(
    params, cfg, calib, p_target=0.5,
    evo=EvoConfig(generations=2, offspring=4, eps=0.1),
    delta=0.25, coord_passes=0, log=print)
with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
    plan.save(f.name)
    print(f"plan saved to {f.name} "
          f"(block ratios {np.round(plan.block_ratios, 2)})")

# --- serving ----------------------------------------------------------------
prompts = jnp.asarray(SyntheticLM(
    dataclasses.replace(data_cfg, seq_len=32)).batch(7))
dense = generate(params, cfg, prompts, 16, None, mode="off")
sparse = generate(params, cfg, prompts, 16, plan.stacked_sp, mode="mask")
agree = float((dense == sparse).mean())
print(f"generated {dense.size} tokens; "
      f"sparse/dense token agreement: {agree:.1%}")
print("dense :", np.asarray(dense[0])[:12])
print("sparse:", np.asarray(sparse[0])[:12])
