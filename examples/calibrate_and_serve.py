"""Serving example: calibrate WiSparse offline, save a *self-contained*
policy artifact, reload it in a "serving fleet" process (no checkpoint
needed to rebuild the sparsity params — the artifact carries ratios,
alphas, taus and the weight-column norms g) and run batched greedy
decoding with the weight-aware sparse path (paper §5.1 recipe: dense
prefill half, sparse decode), comparing outputs against the dense server.

    PYTHONPATH=src python examples/calibrate_and_serve.py
"""
import os as _os
import sys as _sys
_sys.path.insert(0, _os.path.join(_os.path.dirname(__file__), ".."))
_sys.path.insert(0, _os.path.join(_os.path.dirname(__file__), "..", "src"))

import dataclasses
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import pipeline
from repro.core.allocation import EvoConfig
from repro.data import DataConfig, SyntheticLM
from repro.launch.serve import generate
from repro.models import api
from repro.sparsity import SparsityPolicy

cfg = reduced(get_config("llama31_8b"))
params = api.init_model(cfg, 0)
data_cfg = DataConfig(cfg.vocab_size, 48, 4)

# --- offline calibration (one-time, per model) -----------------------------
calib = {"tokens": jnp.asarray(SyntheticLM(data_cfg).batch(0))}
plan = pipeline.run_pipeline(
    params, cfg, calib, p_target=0.5,
    evo=EvoConfig(generations=2, offspring=4, eps=0.1),
    delta=0.25, coord_passes=0, log=print)

# the policy: paper-exact mask numerics on the most sensitive blocks
# (lowest evolutionary prune ratios), mask everywhere else for this demo
policy = plan.to_policy(backend="mask", sensitive_backend="mask")
artifact = tempfile.NamedTemporaryFile(suffix=".npz", delete=False).name
policy.save(artifact, sp=plan.stacked_sp)
print(f"self-contained artifact saved to {artifact} "
      f"(block ratios {np.round(plan.block_ratios, 2)})")

# --- serving fleet: reload without the calibration context -----------------
policy2, sp2 = SparsityPolicy.load(artifact)
assert policy2 == policy

prompts = jnp.asarray(SyntheticLM(
    dataclasses.replace(data_cfg, seq_len=32)).batch(7))
dense = generate(params, cfg, prompts, 16, None,
                 policy=SparsityPolicy.dense())
sparse = generate(params, cfg, prompts, 16, sp2, policy=policy2)
agree = float((dense == sparse).mean())
print(f"generated {dense.size} tokens; "
      f"sparse/dense token agreement: {agree:.1%}")
print("dense :", np.asarray(dense[0])[:12])
print("sparse:", np.asarray(sparse[0])[:12])
