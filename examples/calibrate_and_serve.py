"""Serving example: calibrate a WiSparse *policy ladder* offline, save it
as one self-contained artifact, reload it in a "serving fleet" process
(no checkpoint needed — the artifact carries every rung's policy, its
ratios/alphas/taus and the shared weight-column norms g) and serve with
the SLO-aware adaptive controller switching rungs under load.

Lifecycle demonstrated (the README's "Adaptive serving" section):
  1. calibrate  — one calibration context, warm-started evolutionary
                  search per budget rung (paper §4.3 + ladder warm start)
  2. save/load  — one versioned npz for the whole ladder
  3. serve      — pinned-rung quality check, then the adaptive engine
                  under a request burst (rung switches, zero retraces)

    PYTHONPATH=src python examples/calibrate_and_serve.py
"""
import os as _os
import sys as _sys
_sys.path.insert(0, _os.path.join(_os.path.dirname(__file__), ".."))
_sys.path.insert(0, _os.path.join(_os.path.dirname(__file__), "..", "src"))

import dataclasses
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.allocation import EvoConfig
from repro.data import DataConfig, SyntheticLM
from repro.launch.serve import generate
from repro.models import api
from repro.serving import Engine, EngineConfig, SLOConfig
from repro.sparsity import PolicyLadder, calibrate_ladder

cfg = reduced(get_config("llama31_8b"))
params = api.init_model(cfg, 0)
data_cfg = DataConfig(cfg.vocab_size, 48, 4)

# --- 1. offline calibration (one-time, per model) --------------------------
# One context, three budgets: rung 0 dense, rungs 1-2 warm-started from
# their denser neighbour (tiny evolutionary budgets for the CPU demo).
calib = {"tokens": jnp.asarray(SyntheticLM(data_cfg).batch(0))}
ladder = calibrate_ladder(
    params, cfg, calib, budgets=(0.0, 0.3, 0.6),
    backend="mask",                     # paper-exact numerics for the demo
    evo=EvoConfig(generations=2, offspring=4, eps=0.1),
    warm_generations=1, delta=0.25, log=print)

artifact = tempfile.NamedTemporaryFile(suffix=".npz", delete=False).name
ladder.save(artifact)
print(f"ladder artifact saved to {artifact}; per-rung block prune ratios:")
for b, r in zip(ladder.budgets, ladder.block_ratios):
    print(f"  budget {b:.1f}: {np.round(r, 2)}")

# --- 2. serving fleet: reload without the calibration context --------------
ladder2 = PolicyLadder.load(artifact)
assert ladder2.policies == ladder.policies

# --- 3a. pinned-rung quality check vs the dense server ---------------------
prompts = jnp.asarray(SyntheticLM(
    dataclasses.replace(data_cfg, seq_len=32)).batch(7))
dense = generate(params, cfg, prompts, 16, None)
for i in range(1, len(ladder2)):
    pol, sp = ladder2.rung(i)
    sparse = generate(params, cfg, prompts, 16, sp, policy=pol)
    agree = float((dense == sparse).mean())
    print(f"rung {i} (budget {ladder2.budgets[i]:.1f}): "
          f"vs-dense token agreement {agree:.1%}")

# --- 3b. adaptive serving: the controller rides the burst ------------------
slo = SLOConfig(tpot_p95=1.0, max_queue=1, dwell=2)   # queue-driven demo
engine = Engine(params, cfg,
                EngineConfig(max_slots=2, max_len=48, prefill_chunk=8,
                             slo=slo),
                ladder=ladder2)                        # precompiles rungs
burst = np.asarray(SyntheticLM(
    dataclasses.replace(data_cfg, seq_len=16, global_batch=8)).batch(3))
for b in range(8):
    engine.submit(burst[b], 8)
out = engine.run()
print(f"adaptive engine: {sum(len(t) for t in out.values())} tokens, "
      f"controller {engine.controller.snapshot()}, "
      f"decode retraces after warmup "
      f"{engine.decode_retraces_after_warmup}")
